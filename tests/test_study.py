"""Study grids: product expansion over spec fields (incl. dotted
CellConfig geometry axes and labeled multi-field axes), auto-derived
labels, dedup, Results axis coordinates, and the geometry-planning
invariants (bigger cells plan slower communication; distinct geometries
never share planner state)."""
import numpy as np
import pytest

from repro.api import AsyncExecutor, Experiment, ScenarioSpec, Study, grid
from repro.api.lowering import Row, _plan_key, plan_bucket
from repro.channels.model import CellConfig
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData

DIM = 16


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=260, dim=DIM, seed=0, spread=6.0)
    return full.split(60)


@pytest.fixture(scope="module")
def fleet():
    return tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in [0.7, 2.1])


def _base(fleet, **kw):
    kw.setdefault("name", "cpu2")
    kw.setdefault("policy", "full")
    kw.setdefault("b_max", 8)
    kw.setdefault("hidden", 24)
    # uncompressed payload: geometry must visibly move the comm latency
    kw.setdefault("compression", 1.0)
    return ScenarioSpec(fleet=fleet, **kw)


# ---------------------------------------------------------------------------
# expansion mechanics
# ---------------------------------------------------------------------------


def test_grid_product_expansion_and_coords(fleet):
    base = _base(fleet)
    study = grid(base, partition=["iid", "noniid"],
                 **{"cell.radius_m": [100.0, 300.0]})
    assert isinstance(study, Study)
    assert len(study) == 4                        # full product
    assert study.coord_names == ("partition", "cell_radius_m")
    # declaration-order expansion, later axes fastest
    got = [(s.partition, s.cell.radius_m) for s in study]
    assert got == [("iid", 100.0), ("iid", 300.0),
                   ("noniid", 100.0), ("noniid", 300.0)]
    for s in study:
        coords = study.axis_coords(s)
        assert coords["partition"] == s.partition
        assert coords["cell_radius_m"] == s.cell.radius_m
        # non-swept cell fields keep their base values
        assert s.cell.bandwidth_hz == base.cell.bandwidth_hz
    # label: geometry axis suffixes the name, partition is a label field
    assert study[0].name == "cpu2/radius_m=100"


def test_grid_labeled_axis_bundles_fields(fleet):
    study = grid(_base(fleet),
                 model={"big": dict(hidden=48, depth=3),
                        "small": dict(hidden=16, depth=2)},
                 base_lr=[0.1, 0.2])
    assert len(study) == 4
    big = [s for s in study if study.axis_coords(s)["model"] == "big"]
    assert all(s.hidden == 48 and s.depth == 3 for s in big)
    assert {study.axis_coords(s)["base_lr"] for s in big} == {0.1, 0.2}
    assert big[0].name.startswith("cpu2/model=big/base_lr=0.1")


def test_grid_dedupes_identical_expansions(fleet):
    study = grid(_base(fleet), policy=["full", "full", "online"])
    assert len(study) == 2                        # duplicate value collapsed
    assert [s.policy for s in study] == ["full", "online"]


def test_grid_rejects_bad_axes(fleet):
    base = _base(fleet)
    with pytest.raises(ValueError, match="no field"):
        grid(base, not_a_field=[1, 2])
    with pytest.raises(ValueError, match="no field"):
        grid(base, **{"cell.not_a_knob": [1.0]})
    with pytest.raises(ValueError, match="not a nested dataclass"):
        grid(base, **{"b_max.deep": [1]})
    with pytest.raises(ValueError, match="no values"):
        grid(base, policy=[])
    # axis values still go through ScenarioSpec validation
    with pytest.raises(ValueError, match="policy"):
        grid(base, policy=["propsed"])
    # coordinate-name collisions with built-in Results coords fail loudly
    # instead of producing silently unselectable axes …
    with pytest.raises(ValueError, match="built-in"):
        grid(base, fleet=[base.fleet])
    with pytest.raises(ValueError, match="built-in"):
        grid(base, policy={"a": dict(hidden=16)})
    # … but plain partition/policy/scheme sweeps pass through (the
    # built-in coordinate carries exactly the swept value)
    assert len(grid(base, partition=["iid", "noniid"],
                    policy=["full", "online"])) == 4
    # overlapping axes would silently override each other: reject
    with pytest.raises(ValueError, match="overlapping"):
        grid(base, hidden=[16, 32],
             model={"small": dict(hidden=16, depth=2)})
    with pytest.raises(ValueError, match="overlapping"):
        grid(base, cell=[CellConfig()], **{"cell.radius_m": [100.0]})
    # a policy sweep must actually surface in the policy coordinate:
    # dev/gradient_fl schemes report effective_policy "none"/"full", so
    # the swept rows would be silently unselectable (and duplicated)
    with pytest.raises(ValueError, match="does not survive"):
        grid(base, scheme=["feel", "individual"],
             policy=["proposed", "online"])
    with pytest.raises(ValueError, match="does not survive"):
        grid(_base(fleet, scheme="gradient_fl"), policy=["proposed"])


def test_tuple_valued_axis_selects_by_equality(dataset, fleet):
    """A swept ``seeds`` axis stores tuple coordinates; sel with a tuple
    must match the whole tuple (equality), with a list of tuples by
    membership — not silently return 0 rows."""
    data, test = dataset
    study = grid(_base(fleet), seeds=[(0, 1), (2, 3)])
    res = Experiment(data, test, study).run(periods=2)
    assert res.rows == 4
    one = res.sel(seeds=(0, 1))
    assert one.rows == 2 and set(one.coords["seed"]) == {0, 1}
    both = res.sel(seeds=[(0, 1), (2, 3)])
    assert both.rows == 4
    # plain collection semantics elsewhere are untouched
    assert res.sel(seed=(0, 2)).rows == 2


# ---------------------------------------------------------------------------
# geometry sweeps: coordinates, planning monotonicity, plan-key hygiene
# ---------------------------------------------------------------------------


def test_geometry_grid_single_experiment_with_coords(dataset, fleet):
    """ISSUE-3 acceptance: a cell.radius_m × policy grid runs as ONE
    Experiment (single shape bucket) and the swept geometry comes back as
    a selectable Results coordinate."""
    data, test = dataset
    study = grid(_base(fleet, seeds=(0, 1)), policy=["full", "online"],
                 **{"cell.radius_m": [100.0, 400.0]})
    exp = Experiment(data, test, study)
    assert len(exp.lower()) == 1                  # geometry never splits
    res = exp.run(periods=3)
    assert res.rows == 8
    assert "cell_radius_m" in res.coords
    sub = res.sel(cell_radius_m=400.0, policy="full")
    assert sub.rows == 2
    assert all(s.cell.radius_m == 400.0 for s in sub.coords["spec"])
    # the same cell selected two ways must agree
    by_spec = res.sel(spec=sub.coords["spec"][0])
    np.testing.assert_array_equal(by_spec.losses, sub.losses)


def test_radius_and_bandwidth_move_horizons_monotonically(dataset, fleet):
    """Larger radius → lower rates → longer planned communication latency;
    more bandwidth → higher rates → shorter.  Checked on the host planning
    phase alone (plan_bucket), fixed-batch policy so only geometry moves.
    """
    data, _ = dataset
    radii = [100.0, 200.0, 400.0, 800.0]
    study = grid(_base(fleet, seeds=(0,)), **{"cell.radius_m": radii})
    [bucket] = Experiment(data, None, study).lower()
    plan = plan_bucket(bucket, data, periods=4)
    finals = plan.times[:, -1]                    # rows follow study order
    assert np.all(np.diff(finals) > 0), finals

    bands = [5e6, 10e6, 40e6]
    study_b = grid(_base(fleet, seeds=(0,)),
                   **{"cell.bandwidth_hz": bands})
    [bucket_b] = Experiment(data, None, study_b).lower()
    plan_b = plan_bucket(bucket_b, data, periods=4)
    finals_b = plan_b.times[:, -1]
    assert np.all(np.diff(finals_b) < 0), finals_b


def test_distinct_geometries_never_share_plan_key(fleet):
    """_plan_key must split on the full CellConfig: same fleet/policy/seed
    but different geometry rows plan independently."""
    cells = [CellConfig(), CellConfig(radius_m=400.0),
             CellConfig(bandwidth_hz=20e6), CellConfig(tx_power_dbm=20.0),
             CellConfig(frame_up_s=0.02)]
    rows = [Row(spec=_base(fleet, cell=c), seed=0, indices=(i,))
            for i, c in enumerate(cells)]
    keys = {_plan_key(r) for r in rows}
    assert len(keys) == len(cells)
    # and equal geometry (+ equal everything else) does share
    assert _plan_key(rows[0]) == _plan_key(
        Row(spec=_base(fleet), seed=0, indices=(9,)))


def test_geometry_sweep_values_match_per_cell_runs(dataset, fleet):
    """A geometry grid lowered as one bucket is bit-identical (ledger) /
    tolerance-equal (series) to running each geometry alone."""
    data, test = dataset
    radii = [120.0, 500.0]
    study = grid(_base(fleet, seeds=(0,)), **{"cell.radius_m": radii})
    res = Experiment(data, test, study).run(periods=3,
                                            executor=AsyncExecutor())
    for radius in radii:
        solo = Experiment(data, test,
                          [_base(fleet, cell=CellConfig(radius_m=radius),
                                 seeds=(0,))]).run(periods=3)
        cell = res.sel(cell_radius_m=radius)
        np.testing.assert_array_equal(cell.times, solo.times)
        np.testing.assert_array_equal(cell.global_batch, solo.global_batch)
        np.testing.assert_allclose(cell.losses, solo.losses, atol=1e-6)
        np.testing.assert_allclose(cell.accs, solo.accs, atol=1e-6)


# ---------------------------------------------------------------------------
# bucket-key hygiene for the compression ablation grid
# ---------------------------------------------------------------------------


def test_compress_off_merges_ratios_into_one_bucket(dataset, fleet):
    """compression is structural only while compress=True; the whole
    compress=False column of a (compression × compress) ablation grid
    shares one bucket (ratio still moves the planned payload/latency)."""
    data, test = dataset
    study = grid(_base(fleet, seeds=(0,)), compression=[0.01, 0.1],
                 compress=[True, False])
    buckets = Experiment(data, test, study).lower()
    assert len(buckets) == 3                      # 2 on-ratios + 1 off
    res = Experiment(data, test, study).run(periods=3)
    off = res.sel(compress=False)
    t_small = off.sel(compression=0.01).times[0, -1]
    t_big = off.sel(compression=0.1).times[0, -1]
    assert t_big > t_small                        # payload moved the ledger


# ---------------------------------------------------------------------------
# property tests: grid expand -> Results.sel round-trip (real hypothesis
# when installed, repro.testing.proptest fallback otherwise) + the
# fail-loudly sel contract
# ---------------------------------------------------------------------------

from math import prod  # noqa: E402

from repro.api.results import COORD_NAMES, Results  # noqa: E402
from repro.testing.proptest import given, settings, strategies as st  # noqa: E402,E501

_AXIS_POOL = ("b_max", "base_lr", "cell.radius_m", "users", "compression")


def _draw_axes(rng, n_axes):
    """A random axis dict: distinct fields, unique values per axis."""
    picks = rng.choice(len(_AXIS_POOL), size=n_axes, replace=False)
    axes = {}
    for i in picks:
        name = _AXIS_POOL[i]
        n_vals = int(rng.integers(1, 4))
        if name == "b_max":
            vals = sorted(int(x) for x in rng.choice(
                np.arange(8, 65), size=n_vals, replace=False))
        elif name == "base_lr":
            vals = [round(float(x), 3) for x in rng.choice(
                np.linspace(0.01, 0.3, 30), size=n_vals, replace=False)]
        elif name == "cell.radius_m":
            vals = [float(x) for x in rng.choice(
                np.arange(100.0, 900.0, 50.0), size=n_vals, replace=False)]
        elif name == "users":
            vals = sorted(int(x) for x in rng.choice(
                np.arange(2, 9), size=n_vals, replace=False))
        else:                                      # compression
            vals = [round(float(x), 4) for x in rng.choice(
                np.linspace(0.001, 0.2, 40), size=n_vals, replace=False)]
        axes[name] = vals
    return axes


def _coords_results(study, seeds):
    """A Results over the study's REAL lowered coordinates (built by
    Experiment._coords — no device work, series are zeros)."""
    exp = Experiment(data=None, test=None, specs=study)
    buckets = exp.lower()
    coords = exp._coords(buckets)
    n = exp._n_rows(buckets)
    z = np.zeros((n, 3))
    return Results(coords=coords, losses=z, accs=z, times=z,
                   global_batch=z, n_buckets=len(buckets))


@settings(deadline=None)
@given(seed=st.integers(0, 100_000), n_axes=st.integers(1, 3))
def test_grid_sel_roundtrip_property(seed, n_axes):
    """Random axis dicts: expansion is the full product; every swept
    value is recoverable through sel() on its Results coordinate; the
    per-value selections partition the rows; the full axis-coordinate
    combination isolates exactly one spec's seed rows."""
    fleet = tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                  for f in [0.7, 2.1])
    rng = np.random.default_rng(seed)
    axes = _draw_axes(rng, n_axes)
    base = _base(fleet, seeds=(0, 1))
    study = grid(base, **axes)
    assert len(study) == prod(len(v) for v in axes.values())
    res = _coords_results(study, seeds=(0, 1))
    assert res.rows == 2 * len(study)

    for name, values in axes.items():
        coord = "num_users" if name == "users" else name.replace(".", "_")
        # every swept value is a recoverable coordinate, in declaration
        # order, and the per-value selections partition the rows
        assert res.unique(coord) == tuple(values)
        total = 0
        for v in values:
            sub = res.sel(**{coord: v})
            assert set(sub.coords[coord]) == {v}
            total += sub.rows
        assert total == res.rows

    # the full combination isolates exactly one spec's seed rows
    spec = study[int(rng.integers(len(study)))]
    sub = res.sel(**dict(study.axis_coords(spec)))
    assert sub.rows == 2
    assert set(sub.coords["spec"]) == {spec}


@settings(deadline=None)
@given(seed=st.integers(0, 100_000))
def test_sel_fails_loudly_property(seed):
    """The PR-3 'fail loudly' contract: a non-coordinate raises KeyError,
    an out-of-grid value (on swept AND built-in coordinates) raises
    ValueError — no silently-empty selections."""
    fleet = tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                  for f in [0.7, 2.1])
    rng = np.random.default_rng(seed)
    axes = _draw_axes(rng, int(rng.integers(1, 3)))
    study = grid(_base(fleet), **axes)
    res = _coords_results(study, seeds=(0,))
    with pytest.raises(KeyError):
        res.sel(definitely_not_a_coordinate=1)
    for name, values in axes.items():
        coord = "num_users" if name == "users" else name.replace(".", "_")
        with pytest.raises(ValueError, match="matches no row"):
            res.sel(**{coord: -12345})
        with pytest.raises(ValueError, match="matches no row"):
            res.sel(**{coord: [-12345, -54321]})   # collection form too
    with pytest.raises(ValueError, match="matches no row"):
        res.sel(policy="not-a-policy")
    with pytest.raises(ValueError, match="matches no row"):
        res.sel(seed=99999)
    # empty INTERSECTION of individually-valid values stays allowed
    first = next(iter(axes))
    coord = "num_users" if first == "users" else first.replace(".", "_")
    v = axes[first][0]
    sub = res.sel(**{coord: v})
    assert set(sub.coords[coord]) == {v}

"""Substrate tests: channels, compression, data pipeline, optimizers,
checkpointing — unit + property tests (real ``hypothesis`` when
installed, ``repro.testing.proptest`` fallback otherwise)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing.proptest import given, settings, strategies as st

from repro.channels.model import Cell, path_loss_db
from repro.compression.sbc import compress_dense, compressed_bits, sbc_tensor
from repro.data.pipeline import (ClassificationData, FederatedBatcher,
                                 TokenData, partition_iid, partition_noniid)
from repro.optim import adamw, apply_updates, momentum, sgd
from repro import checkpoint


# ---------------------------------------------------------------------------
# channels
# ---------------------------------------------------------------------------


class TestChannel:
    def test_path_loss_monotone(self):
        d = np.array([0.01, 0.05, 0.1, 0.2])
        pl = path_loss_db(d)
        assert np.all(np.diff(pl) > 0)

    def test_rate_decreases_with_distance(self):
        cell = Cell.make(0)
        r = cell.avg_rate(np.array([0.02, 0.05, 0.1, 0.2]))
        assert np.all(np.diff(r) < 0)
        assert np.all(r > 0)

    def test_monte_carlo_expectation(self):
        """eq (5): MC average close to numerically-integrated expectation."""
        cell = Cell.make(1)
        cell.cfg = cell.cfg.__class__(fading_samples=200_000)
        d = np.array([0.1])
        r = cell.avg_rate(d)[0]
        # numeric integral over Exp(1) fading
        pl = path_loss_db(d)[0]
        snr = 10 ** ((cell.cfg.tx_power_dbm - pl
                      - (cell.cfg.noise_dbm_per_hz
                         + 10 * np.log10(cell.cfg.bandwidth_hz))) / 10)
        h = np.random.default_rng(0).exponential(size=2_000_000)
        want = cell.cfg.bandwidth_hz * np.mean(np.log2(1 + snr * h))
        assert r == pytest.approx(want, rel=0.01)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


class TestSBC:
    def test_sparsity(self):
        g = jnp.asarray(np.random.default_rng(0).normal(size=4000))
        out = sbc_tensor(g, 0.01)
        nnz = int(jnp.sum(out != 0))
        assert nnz <= int(0.01 * 4000) + 1

    def test_single_sign_binarization(self):
        g = jnp.asarray(np.random.default_rng(1).normal(size=4000))
        out = np.asarray(sbc_tensor(g, 0.01))
        vals = np.unique(out[out != 0])
        assert len(vals) == 1                   # one magnitude, one sign

    def test_kept_entries_subset_of_topk(self):
        g = jnp.asarray(np.random.default_rng(2).normal(size=1000))
        out = np.asarray(sbc_tensor(g, 0.05))
        k = 50
        topk = set(np.argsort(-np.abs(np.asarray(g)))[:k])
        assert set(np.nonzero(out)[0]).issubset(topk)

    def test_error_feedback_reduces_bias(self):
        """With residual accumulation, the long-run compressed average
        tracks the true gradient much better than without (EF property)."""
        rng = np.random.default_rng(3)
        true = jnp.asarray(rng.normal(size=500))

        def run(use_ef):
            res = None
            acc = jnp.zeros(500)
            for _ in range(60):
                approx, res = compress_dense(true, 0.02, res)
                if not use_ef:
                    res = None
                acc = acc + approx
            return float(jnp.linalg.norm(acc / 60 - true)
                         / jnp.linalg.norm(true))

        err_ef, err_plain = run(True), run(False)
        assert err_ef < 0.7 * err_plain
        assert err_ef < 0.6

    def test_payload_model(self):
        assert compressed_bits(1_000_000, 0.005, 64) == \
            pytest.approx(0.005 * 64 * 1e6)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(64, 3000), ratio=st.floats(0.005, 0.2),
           seed=st.integers(0, 100))
    def test_sbc_properties(self, n, ratio, seed):
        g = jnp.asarray(np.random.default_rng(seed).normal(size=n))
        out = np.asarray(sbc_tensor(g, ratio))
        nnz = int((out != 0).sum())
        assert nnz <= max(1, int(round(n * ratio))) + 1
        if nnz:
            signs = np.sign(out[out != 0])
            assert len(np.unique(signs)) == 1
            # kept positions preserve the original sign
            orig = np.sign(np.asarray(g))[out != 0]
            assert np.all(orig == signs)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


class TestData:
    def test_partitions_disjoint_cover(self):
        parts = partition_iid(1000, 7, 0)
        allidx = np.concatenate(parts)
        assert len(allidx) == 1000
        assert len(np.unique(allidx)) == 1000

    def test_noniid_label_concentration(self):
        data = ClassificationData.synthetic(n=2000, dim=8, seed=0)
        parts = partition_noniid(data.y, 10, seed=0)
        allidx = np.concatenate(parts)
        assert len(np.unique(allidx)) == 2000
        # pathological split: most devices see <= 3 classes (2 shards)
        n_few = sum(len(np.unique(data.y[p])) <= 3 for p in parts)
        assert n_few >= 7

    def test_batcher_weights_match_plan(self):
        parts = partition_iid(500, 4, 0)
        b = FederatedBatcher(parts, slot=16, seed=0)
        idx, w = b.sample(np.array([3, 16, 1, 8]))
        assert idx.shape == (4, 16) and w.shape == (4, 16)
        np.testing.assert_array_equal(w.sum(1), [3, 16, 1, 8])

    def test_eq1_weighted_aggregation_equivalence(self):
        """Masked weighted-mean gradient == eq. (1) Σ B_k·ḡ_k / Σ B_k."""
        rng = np.random.default_rng(0)
        K, slot, D = 3, 8, 5
        x = rng.normal(size=(K, slot, D)).astype(np.float32)
        y = rng.integers(0, 2, size=(K, slot)).astype(np.int32)
        w = np.zeros((K, slot), np.float32)
        bk = [2, 8, 5]
        for k in range(K):
            w[k, :bk[k]] = 1

        wt = jnp.asarray(rng.normal(size=(D,)).astype(np.float32))

        def loss(wt_, xf, yf, wf):
            logit = xf @ wt_
            nll = jnp.square(logit - yf)        # simple per-example loss
            return jnp.sum(nll * wf) / jnp.sum(wf)

        # flattened weighted loss gradient
        g_flat = jax.grad(loss)(wt, jnp.asarray(x.reshape(-1, D)),
                                jnp.asarray(y.reshape(-1)),
                                jnp.asarray(w.reshape(-1)))
        # per-device mean gradients combined per eq. (1)
        gs = []
        for k in range(K):
            gk = jax.grad(loss)(wt, jnp.asarray(x[k]), jnp.asarray(y[k]),
                                jnp.asarray(w[k]))
            gs.append(np.asarray(gk) * bk[k])
        g_eq1 = np.sum(gs, axis=0) / np.sum(bk)
        np.testing.assert_allclose(np.asarray(g_flat), g_eq1, rtol=1e-5)

    def test_token_data_learnable(self):
        t = TokenData.synthetic(n=64, seq=32, vocab=128, seed=0)
        assert t.tokens.shape == (64, 33)
        assert t.tokens.min() >= 0 and t.tokens.max() < 128


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


class TestOptim:
    def _params(self):
        return {"w": jnp.asarray([1.0, 2.0]), "b": jnp.asarray(0.5)}

    def test_sgd(self):
        opt = sgd()
        p = self._params()
        g = jax.tree_util.tree_map(jnp.ones_like, p)
        upd, _ = opt.update(g, opt.init(p), p, 0.1)
        new = apply_updates(p, upd)
        np.testing.assert_allclose(new["w"], [0.9, 1.9])

    def test_momentum_accumulates(self):
        opt = momentum(0.9)
        p = self._params()
        s = opt.init(p)
        g = jax.tree_util.tree_map(jnp.ones_like, p)
        upd1, s = opt.update(g, s, p, 0.1)
        upd2, s = opt.update(g, s, p, 0.1)
        np.testing.assert_allclose(np.asarray(upd2["w"]),
                                   np.asarray(upd1["w"]) * 1.9)

    def test_adamw_direction_and_decay(self):
        opt = adamw(weight_decay=0.0)
        p = self._params()
        s = opt.init(p)
        g = {"w": jnp.asarray([1.0, -1.0]), "b": jnp.asarray(0.0)}
        upd, s = opt.update(g, s, p, 0.1)
        assert upd["w"][0] < 0 < upd["w"][1]
        # bias-corrected first step magnitude ~ lr
        np.testing.assert_allclose(np.abs(np.asarray(upd["w"])), 0.1,
                                   rtol=1e-3)

    def test_quadratic_convergence(self):
        opt = adamw()
        p = {"x": jnp.asarray(5.0)}
        s = opt.init(p)
        for _ in range(300):
            g = jax.grad(lambda q: jnp.square(q["x"]))(p)
            upd, s = opt.update(g, s, p, 0.05)
            p = apply_updates(p, upd)
        assert abs(float(p["x"])) < 0.05


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.asarray([1, 2], jnp.int32)},
                "d": [jnp.zeros(()), jnp.ones((4,), jnp.bfloat16)]}
        path = os.path.join(tmp_path, "ckpt.msgpack")
        checkpoint.save(path, tree)
        out = checkpoint.restore(path, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype

    def test_state_roundtrip(self, tmp_path):
        params = {"w": jnp.ones((3, 3))}
        opt = {"m": {"w": jnp.zeros((3, 3))}, "t": jnp.asarray(7)}
        path = os.path.join(tmp_path, "state.msgpack")
        checkpoint.save_state(path, 42, params, opt)
        step, p, o, _ = checkpoint.restore_state(path, params, opt)
        assert step == 42
        np.testing.assert_array_equal(np.asarray(o["t"]), 7)

    def test_shape_mismatch_raises(self, tmp_path):
        path = os.path.join(tmp_path, "x.msgpack")
        checkpoint.save(path, {"a": jnp.zeros((2,))})
        with pytest.raises(ValueError):
            checkpoint.restore(path, {"a": jnp.zeros((3,))})

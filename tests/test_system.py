"""End-to-end system behaviour: the FEEL loop trains a model on non-IID
federated data, the proposed policy wins on simulated wall-clock, and the
big-model train step reproduces eq. (1) aggregation semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed.trainer import FeelSimulation, run_scheme
from repro.fed.train_step import TrainState, make_train_step
from repro.models.model import Runtime, init
from repro.configs import ARCHS
from repro.optim import momentum, sgd


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=2200, dim=128, seed=0, spread=6.0)
    return full.split(300)


@pytest.fixture(scope="module")
def fleet():
    return [DeviceProfile(kind="cpu", f_cpu=f * 1e9)
            for f in [0.7, 0.7, 1.4, 1.4, 2.1, 2.1]]


class TestFeelLoop:
    def test_noniid_convergence(self, dataset, fleet):
        data, test = dataset
        sim = FeelSimulation(fleet, data, test, partition="noniid",
                             policy="proposed", b_max=64, base_lr=0.15)
        res = sim.run(100, eval_every=25)
        assert res.accs[-1] > 0.75
        assert res.losses[-1] < res.losses[0]

    def test_compression_does_not_break_training(self, dataset, fleet):
        data, test = dataset
        sim = FeelSimulation(fleet, data, test, partition="iid",
                             policy="proposed", b_max=64, base_lr=0.15)
        sim.compress = True
        res = sim.run(80, eval_every=40)
        assert res.accs[-1] > 0.6

    def test_proposed_faster_than_fixed_policies(self, dataset, fleet):
        """Figs. 4-5: time to reach target accuracy, proposed < baselines."""
        data, test = dataset
        times = {}
        for pol in ["proposed", "online", "full"]:
            sim = FeelSimulation(fleet, data, test, partition="iid",
                                 policy=pol, b_max=64, base_lr=0.15,
                                 seed=1)
            res = sim.run(60, eval_every=15)
            times[pol] = res.speed(0.60)
        assert times["proposed"] < times["online"]
        assert times["proposed"] < times["full"]

    def test_multiple_local_updates(self, dataset, fleet):
        """Paper §VII extension: tau>1 local steps per period still
        converges and costs proportionally more simulated time."""
        data, test = dataset
        sim = FeelSimulation(fleet, data, test, partition="iid",
                             policy="proposed", b_max=32, base_lr=0.1,
                             local_steps=3)
        res = sim.run(30, eval_every=15)
        assert res.losses[-1] < res.losses[0]
        sim1 = FeelSimulation(fleet, data, test, partition="iid",
                              policy="proposed", b_max=32, base_lr=0.1,
                              local_steps=1)
        res1 = sim1.run(30, eval_every=15)
        assert res.times[-1] > res1.times[-1]      # tau local-compute cost

    def test_scheduler_xi_estimator_updates(self, dataset, fleet):
        data, test = dataset
        sim = FeelSimulation(fleet, data, test, partition="iid", b_max=32)
        xi0 = sim.scheduler.xi_est.xi
        sim.run(12, eval_every=6)
        assert sim.scheduler.xi_est.xi != xi0


class TestSchemes:
    def test_gradient_fl_runs(self, dataset, fleet):
        data, test = dataset
        r = run_scheme("gradient_fl", fleet, data, test, "iid", 20,
                       eval_every=10)
        assert len(r.accs) >= 2 and np.isfinite(r.losses[-1])

    def test_individual_vs_model_fl(self, dataset, fleet):
        data, test = dataset
        ri = run_scheme("individual", fleet, data, test, "noniid", 15,
                        eval_every=15)
        rm = run_scheme("model_fl", fleet, data, test, "noniid", 15,
                        eval_every=15)
        assert np.isfinite(ri.accs[-1]) and np.isfinite(rm.accs[-1])
        # model FL pays for parameter upload: slower simulated clock
        assert rm.times[-1] > ri.times[-1]


class TestBigModelTrainStep:
    def test_weighted_step_matches_eq1(self):
        """train_step with masked weights == manual eq.(1) gradient combo."""
        cfg = ARCHS["qwen1.5-4b"].reduced()
        rt = Runtime()
        params = init(cfg, jax.random.key(0))
        opt = sgd()
        step = make_train_step(cfg, rt, opt)
        K, slot, S = 2, 2, 16
        toks = jax.random.randint(jax.random.key(1), (K * slot, S + 1), 0,
                                  cfg.vocab)
        w = np.zeros((K, slot), np.float32)
        w[0, :1] = 1.0                        # B_0 = 1
        w[1, :2] = 1.0                        # B_1 = 2
        batch = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "weights": jnp.broadcast_to(
                jnp.asarray(w.reshape(-1))[:, None],
                (K * slot, S)).astype(jnp.float32),
        }
        state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
        new_state, metrics = step(state, batch, 0.1)
        assert np.isfinite(float(metrics["loss"]))

        # manual per-device grads, combined by B_k (eq. 1)
        from repro.fed.train_step import make_loss_fn
        loss_fn = make_loss_fn(cfg, rt)

        def dev_grad(sl):
            b = {k: v[sl] for k, v in batch.items()}
            return jax.grad(lambda p: loss_fn(p, b)[0])(params)

        g0 = dev_grad(slice(0, slot))
        g1 = dev_grad(slice(slot, 2 * slot))
        combo = jax.tree_util.tree_map(
            lambda a, b_: (1 * a + 2 * b_) / 3.0, g0, g1)
        # reconstruct applied gradient: sgd => g = (old - new)/lr
        got = jax.tree_util.tree_map(
            lambda new, old: (old - new) / 0.1, new_state.params,
            state.params)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(combo)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, rtol=5e-3)

    def test_compress_uplink_step_runs(self):
        cfg = ARCHS["mamba2-2.7b"].reduced()
        rt = Runtime()
        params = init(cfg, jax.random.key(0))
        opt = momentum()
        step = jax.jit(make_train_step(cfg, rt, opt, compress_uplink=True,
                                       compress_ratio=0.01))
        toks = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                 "weights": jnp.ones((2, 16))}
        state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
        state, metrics = step(state, batch, 0.05)
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["grad_norm"]) > 0

"""Massive-fleet topology: per-round client sampling, cell→edge→cloud
hierarchical aggregation, and K-banded sub-bucketing (PR 8).

The contracts under test:

* sampling is *data*, not structure — sampled and unsampled scenarios
  share a bucket/program, a full-participation sampler is bitwise the
  unsampled path, and every rng stream (positions, fading, batcher,
  policy draws) is untouched by who sat out;
* the time-varying participation mask dominates every cross-user
  reduction: garbage in a sampled-out user's schedule columns never
  reaches any result;
* the hierarchical engine degenerates to the flat one at
  cells=edges=agg_every=1, and cloud rounds alone pay the backhaul;
* K-banded sub-bucketing is invisible to results (bitwise ledgers,
  identical selections) and compiles one program per power-of-two band.
"""
import numpy as np
import pytest

from repro.api import Experiment, ScenarioSpec
from repro.core import DeviceProfile, FeelScheduler
from repro.core.scheduler import DevScheduler, plan_horizons_batch
from repro.core.solver import FleetRows, fixed_slot_rows
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.testing import no_retrace
from repro.topology import (ParticipationSampler, Sampling, Topology,
                            band_width, split_bands)

# distinctive shapes (no other test module uses dim=26 / hidden=52 /
# b_max=18) so the lru-cached engine programs are fresh and the
# trace-count assertions below are exact
DIM, HIDDEN, BMAX = 26, 52, 18


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=400, dim=DIM, seed=0, spread=6.0)
    return full.split(80)


def _fleet(k):
    return tuple(DeviceProfile(kind="cpu", f_cpu=(0.6 + 0.3 * i) * 1e9)
                 for i in range(k))


def _spec(k, **kw):
    kw.setdefault("name", f"K{k}")
    kw.setdefault("policy", "proposed")
    kw.setdefault("b_max", BMAX)
    kw.setdefault("base_lr", 0.15)
    kw.setdefault("hidden", HIDDEN)
    return ScenarioSpec(fleet=_fleet(k), **kw)


# ---------------------------------------------------------------------------
# spec surface and validation
# ---------------------------------------------------------------------------


def test_sampling_validation():
    with pytest.raises(ValueError, match="exactly one"):
        Sampling()
    with pytest.raises(ValueError, match="exactly one"):
        Sampling(size=2, fraction=0.5)
    with pytest.raises(ValueError, match="positive int"):
        Sampling(size=0)
    with pytest.raises(ValueError, match="positive int"):
        Sampling(size=True)
    with pytest.raises(ValueError, match=r"\(0, 1\]"):
        Sampling(fraction=0.0)
    with pytest.raises(ValueError, match=r"\(0, 1\]"):
        Sampling(fraction=1.5)
    assert Sampling(size=3).s_of(8) == 3
    assert Sampling(size=30).s_of(8) == 8          # clamp to the fleet
    assert Sampling(fraction=0.5).s_of(8) == 4
    assert Sampling(fraction=0.01).s_of(8) == 1    # never an empty cohort
    with pytest.raises(TypeError, match="Sampling"):
        _spec(4, sampling=0.5)


def test_topology_validation():
    with pytest.raises(ValueError, match="edges"):
        Topology(cells=2, edges=3)
    with pytest.raises(ValueError, match="positive int"):
        Topology(cells=0)
    with pytest.raises(ValueError, match="positive int"):
        Topology(agg_every=0)
    with pytest.raises(ValueError, match="backhaul"):
        Topology(backhaul_bps=0.0)
    t = Topology(cells=4, edges=2, agg_every=3, backhaul_bps=2e9)
    assert t.structural_key() == (4, 2, 3)          # backhaul is a value
    with pytest.raises(TypeError, match="Topology"):
        _spec(4, topology=(2, 1))
    with pytest.raises(ValueError, match="aggregation tier"):
        _spec(4, scheme="individual", topology=Topology(cells=2, edges=1))
    with pytest.raises(ValueError, match="populate"):
        _spec(2, topology=Topology(cells=3, edges=1))
    # structural: topology in the bucket key, sampling not
    base = _spec(4)
    assert _spec(4, sampling=Sampling(size=2)).bucket_key() \
        == base.bucket_key()
    assert _spec(4, topology=Topology(cells=2, edges=1)).bucket_key() \
        != base.bucket_key()
    # backhaul-only topology differences still share a program
    assert _spec(4, topology=Topology(cells=2, edges=1,
                                      backhaul_bps=1e9)).bucket_key() \
        == _spec(4, topology=Topology(cells=2, edges=1,
                                      backhaul_bps=9e9)).bucket_key()


def test_topology_partition_helpers():
    t = Topology(cells=3, edges=2, agg_every=2)
    cells = t.cell_of_users(7)
    assert cells.shape == (7,) and set(cells) == {0, 1, 2}
    masks = t.cell_masks(7)
    np.testing.assert_array_equal(masks.sum(0), np.ones(7))   # a partition
    member = t.member_matrix(7, k_pad=10)
    assert member.shape == (2, 10)
    np.testing.assert_array_equal(member[:, 7:], 0.0)         # pad columns
    np.testing.assert_array_equal(member[:, :7].sum(0), np.ones(7))
    np.testing.assert_array_equal(
        t.cloud_rounds(6), np.array([0, 1, 0, 1, 0, 1], np.float32))
    # chunk resumability: offset continues the cadence mid-stream
    np.testing.assert_array_equal(
        np.concatenate([t.cloud_rounds(4), t.cloud_rounds(2, offset=4)]),
        t.cloud_rounds(6))


def test_band_helpers():
    assert [band_width(k) for k in (1, 2, 3, 8, 9, 1024, 1025)] \
        == [1, 2, 4, 8, 16, 1024, 2048]
    with pytest.raises(ValueError):
        band_width(0)
    from types import SimpleNamespace
    rows = [SimpleNamespace(spec=SimpleNamespace(k=k))
            for k in (3, 5, 8, 1024, 2, 700)]
    bands = split_bands(rows)
    assert {b: sorted(r.spec.k for r in v) for b, v in bands.items()} \
        == {4: [3], 8: [5, 8], 1024: [700, 1024], 2: [2]}


def test_sampler_stream_invariance():
    """One draw per planned period: chunked draws equal one monolithic
    draw, and two samplers with the same seeds agree exactly."""
    a = ParticipationSampler(Sampling(size=3), k=9, seed=5)
    b = ParticipationSampler(Sampling(size=3), k=9, seed=5)
    mono = a.draw(7)
    chunked = np.concatenate([b.draw(4), b.draw(3)])
    np.testing.assert_array_equal(mono, chunked)
    assert mono.shape == (7, 9) and mono.dtype == np.float32
    np.testing.assert_array_equal(mono.sum(1), np.full(7, 3.0))


# ---------------------------------------------------------------------------
# scheduler: sampling restricts every allocation to the cohort
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["proposed", "online", "full", "random"])
def test_full_participation_horizon_is_bitwise_unsampled(policy):
    devs = _fleet(6)
    h1 = FeelScheduler(devices=devs, n_params=900, policy=policy,
                       b_max=BMAX).plan_horizon(5)
    h2 = FeelScheduler(devices=devs, n_params=900, policy=policy,
                       b_max=BMAX,
                       sampling=Sampling(size=6)).plan_horizon(5)
    for f in ("batch", "tau_up", "tau_down", "lr", "latency",
              "global_batch"):
        np.testing.assert_array_equal(getattr(h1, f), getattr(h2, f))


@pytest.mark.parametrize("policy", ["proposed", "full"])
def test_sampled_horizon_masks_absentees(policy):
    s = FeelScheduler(devices=_fleet(8), n_params=900, policy=policy,
                      b_max=BMAX, sampling=Sampling(size=3))
    h = s.plan_horizon(6)
    assert h.participation.shape == (6, 8)
    np.testing.assert_array_equal(h.participation.sum(1), np.full(6, 3.0))
    np.testing.assert_array_equal((h.batch > 0).astype(np.float32),
                                  h.participation)
    np.testing.assert_array_equal(h.tau_up[h.participation < 0.5], 0.0)
    np.testing.assert_array_equal(h.global_batch,
                                  h.batch.sum(1).astype(np.int64))


def test_sampled_chunked_horizon_bitwise_monolithic():
    mk = lambda: FeelScheduler(devices=_fleet(6), n_params=900,     # noqa
                               b_max=BMAX, seed=11,
                               sampling=Sampling(fraction=0.5))
    hm = mk().plan_horizon(8)
    s = mk()
    hc = [s.plan_horizon(5), s.plan_horizon(3)]
    for f in ("batch", "latency", "participation"):
        np.testing.assert_array_equal(
            getattr(hm, f),
            np.concatenate([getattr(h, f) for h in hc]))


def test_sampled_fused_batch_planning_bitwise_solo():
    mk = lambda i: FeelScheduler(devices=_fleet(5), n_params=900,   # noqa
                                 b_max=BMAX, seed=i,
                                 sampling=Sampling(size=2))
    fused = plan_horizons_batch([mk(0), mk(1), mk(2)], 5)
    solo = [mk(i).plan_horizon(5) for i in range(3)]
    for a, b in zip(fused, solo):
        for f in ("batch", "tau_up", "latency", "participation"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))


def test_masked_rows_match_compact_subset_solve():
    """Equal-slot allocation over a masked fleet equals solving the
    compacted participant subset outright (mask-exclusion property)."""
    devs = _fleet(6)
    keep = np.array([1, 0, 1, 1, 0, 1], float)
    rng = np.random.default_rng(0)
    rates = rng.uniform(1e6, 5e6, size=(2, 3, 6))
    batch = np.full((3, 6), 4.0)
    fr = FleetRows.from_devices(devs, 3).with_mask(
        np.broadcast_to(keep, (3, 6)))
    tu, td, lat = fixed_slot_rows(fr, batch * keep, rates[0], rates[1],
                                  1e5, 0.01, 0.01)
    sub = [d for d, m in zip(devs, keep) if m > 0.5]
    tu_s, td_s, lat_s = fixed_slot_rows(sub, batch[:, keep > 0.5],
                                        rates[0][:, keep > 0.5],
                                        rates[1][:, keep > 0.5],
                                        1e5, 0.01, 0.01)
    np.testing.assert_array_equal(tu[:, keep > 0.5], tu_s)
    np.testing.assert_array_equal(td[:, keep > 0.5], td_s)
    np.testing.assert_array_equal(tu[:, keep < 0.5], 0.0)
    np.testing.assert_array_equal(lat, lat_s)


def test_topo_cloud_rounds_pay_backhaul():
    t_fast = Topology(cells=2, edges=1, agg_every=3, backhaul_bps=1e12)
    t_slow = Topology(cells=2, edges=1, agg_every=3, backhaul_bps=1e6)
    mk = lambda t: FeelScheduler(devices=_fleet(6), n_params=900,   # noqa
                                 b_max=BMAX, seed=3, topology=t)
    hf, hs = mk(t_fast).plan_horizon(6), mk(t_slow).plan_horizon(6)
    np.testing.assert_array_equal(hf.cloud, [0, 0, 1, 0, 0, 1])
    np.testing.assert_array_equal(hf.batch, hs.batch)     # same allocation
    diff = hs.latency - hf.latency
    gap = (t_slow.backhaul_roundtrip(mk(t_slow).payload_bits)
           - t_fast.backhaul_roundtrip(mk(t_fast).payload_bits))
    np.testing.assert_allclose(diff[hf.cloud > 0.5], gap)
    np.testing.assert_array_equal(diff[hf.cloud < 0.5], 0.0)


def test_topo_chunked_horizon_bitwise_monolithic():
    t = Topology(cells=2, edges=2, agg_every=3)
    mk = lambda: FeelScheduler(devices=_fleet(6), n_params=900,     # noqa
                               b_max=BMAX, seed=7, topology=t,
                               sampling=Sampling(size=3))
    hm = mk().plan_horizon(8)
    s = mk()
    hc = [s.plan_horizon(5), s.plan_horizon(3)]
    for f in ("batch", "latency", "cloud", "participation"):
        np.testing.assert_array_equal(
            getattr(hm, f),
            np.concatenate([getattr(h, f) for h in hc]))


def test_dev_scheduler_sampling():
    devs = _fleet(5)
    parts = [np.arange(i * 40, (i + 1) * 40) for i in range(5)]
    mk = lambda samp: DevScheduler(devices=devs, parts=parts,       # noqa
                                   batch=8, payload_bits=1e6,
                                   upload=True, seed=2, sampling=samp)
    h0, hfull = mk(None).plan_horizon(4), mk(Sampling(size=5)).plan_horizon(4)
    for f in ("idx", "times", "tau_up", "tau_down"):
        np.testing.assert_array_equal(getattr(h0, f), getattr(hfull, f))
    hs = mk(Sampling(size=2)).plan_horizon(4)
    np.testing.assert_array_equal(hs.idx, h0.idx)   # idx stream untouched
    np.testing.assert_array_equal(hs.participation.sum(1), np.full(4, 2.0))
    # the cohort splits the frame: slot = frame / S for participants
    live = hs.participation > 0.5
    np.testing.assert_allclose(hs.tau_up[live], 0.010 / 2.0)
    np.testing.assert_array_equal(hs.tau_up[~live], 0.0)


# ---------------------------------------------------------------------------
# engine: the time-varying mask dominates every reduction
# ---------------------------------------------------------------------------


def test_sampled_out_columns_are_dead(dataset):
    """Garbage in a sampled-out user's schedule columns never reaches the
    series, the carried params, or the residuals — the device-program
    face of the participation contract."""
    import jax.numpy as jnp
    data, test = dataset
    spec = _spec(5, sampling=Sampling(size=2), seeds=(3,))
    exp = Experiment(data, test, [spec])
    bucket = exp.lower()[0]
    from repro.api.lowering import plan_bucket, _init_params_batch
    plan = plan_bucket(bucket, data, 4)
    active = plan.payload["active"]            # (1, 4, 5) time-varying
    assert active.ndim == 3
    params0 = _init_params_batch(bucket.rows, plan.input_dim)
    import jax
    k_pad = bucket.k_pad
    residual0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros((p.shape[0], k_pad) + p.shape[1:], p.dtype),
        params0)

    def run(schedules):
        return engine.run_trajectory_batch(
            params0, residual0, schedules, data, test, active=active)

    clean = run(plan.payload["schedules"])
    s = plan.payload["schedules"][0]
    dead = active[0] < 0.5                     # (P, K) absentee positions
    weight = s.weight.copy()
    batch = s.batch.copy()
    weight[dead] = 1e6                         # poison every dead column
    batch[dead] = 9.9e5
    from dataclasses import replace
    poisoned = run([replace(s, weight=weight, batch=batch)])
    for a, b in zip(jax.tree_util.tree_leaves(clean),
                    jax.tree_util.tree_leaves(poisoned)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hier_degenerates_to_flat(dataset):
    """cells=edges=agg_every=1 routes every user to one replica and
    merges it with itself every period: allocation bitwise the flat
    plan, trajectories equal to float tolerance (different program)."""
    data, test = dataset
    t1 = Topology(cells=1, edges=1, agg_every=1, backhaul_bps=1e15)
    flat = Experiment(data, test, [_spec(5, seeds=(0, 1))]).run(periods=5)
    hier = Experiment(data, test,
                      [_spec(5, seeds=(0, 1), topology=t1)]).run(periods=5)
    np.testing.assert_array_equal(flat.global_batch, hier.global_batch)
    np.testing.assert_allclose(np.asarray(flat.losses),
                               np.asarray(hier.losses),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(flat.accs),
                               np.asarray(hier.accs), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# API: buckets, bit-identity, bands
# ---------------------------------------------------------------------------


def test_sampling_shares_bucket_and_program(dataset):
    """Sampled and unsampled rows are one bucket, one trace; full
    participation is bitwise the unsampled row."""
    data, test = dataset
    specs = [_spec(6, seeds=(0,)),
             _spec(6, seeds=(0,), sampling=Sampling(size=6)),
             _spec(6, seeds=(0,), sampling=Sampling(size=2))]
    exp = Experiment(data, test, specs)
    assert len(exp.lower()) == 1
    with no_retrace(expect=1):
        res = exp.run(periods=5)
    plain = np.asarray(res.losses)[0]
    full = np.asarray(res.losses)[1]
    np.testing.assert_array_equal(plain, full)
    np.testing.assert_array_equal(res.times[0], res.times[1])


def test_sampled_padded_row_bitwise_solo(dataset):
    """A sampled row inside a K-heterogeneous padded bucket reproduces
    its solo run: ledgers bitwise, trajectories to float tolerance."""
    data, test = dataset
    samp = Sampling(size=2, seed=4)
    mixed = Experiment(data, test, [
        _spec(4, seeds=(0, 1), sampling=samp),
        _spec(7, seeds=(0, 1), sampling=samp)]).run(periods=5)
    for k in (4, 7):
        solo = Experiment(data, test,
                          [_spec(k, seeds=(0, 1), sampling=samp)]
                          ).run(periods=5)
        cell = mixed.sel(fleet=f"K{k}")
        np.testing.assert_array_equal(cell.times, solo.times)
        np.testing.assert_array_equal(cell.global_batch, solo.global_batch)
        np.testing.assert_allclose(np.asarray(cell.losses),
                                   np.asarray(solo.losses),
                                   atol=1e-5, rtol=1e-5)


def test_banded_lowering_matches_unbanded(dataset):
    """bands=True: bitwise-identical host ledgers, device series equal to
    the cross-padding float tolerance (a band-4 and a grid-max-7 program
    pad the user axis differently — the PR-4 1-ulp caveat), identical
    selection surface — and one compiled program per power-of-two band
    (trace-ledger enforced)."""
    data, test = dataset
    specs = [_spec(3, seeds=(0, 1)), _spec(4, seeds=(0,)),
             _spec(7, seeds=(0,))]
    flat = Experiment(data, test, specs).run(periods=4)
    exp = Experiment(data, test, specs)
    buckets = exp.lower(bands=True)
    assert sorted((b.band, b.k_pad) for b in buckets) == [(4, 4), (8, 8)]
    with no_retrace(expect=2):                 # one program per band
        banded = exp.run(periods=4, bands=True)
    np.testing.assert_array_equal(flat.times, banded.times)
    np.testing.assert_array_equal(flat.global_batch, banded.global_batch)
    np.testing.assert_allclose(np.asarray(flat.losses),
                               np.asarray(banded.losses),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(flat.accs),
                               np.asarray(banded.accs),
                               atol=1e-5, rtol=1e-5)
    for k in (3, 4, 7):                        # invisible to selection
        cell_b, cell_f = (banded.sel(fleet=f"K{k}"),
                          flat.sel(fleet=f"K{k}"))
        assert cell_b.rows == cell_f.rows
        np.testing.assert_array_equal(cell_b.times, cell_f.times)


def test_topo_sampled_chunked_run_matches_monolithic(dataset):
    data, test = dataset
    spec = _spec(6, seeds=(0,), topology=Topology(cells=2, edges=2,
                                                  agg_every=2),
                 sampling=Sampling(size=3))
    mono = Experiment(data, test, [spec]).run(periods=6)
    chunked = Experiment(data, test, [spec]).run(periods=6, replan=2)
    np.testing.assert_array_equal(mono.times, chunked.times)
    np.testing.assert_array_equal(mono.global_batch, chunked.global_batch)
    np.testing.assert_allclose(np.asarray(mono.losses),
                               np.asarray(chunked.losses),
                               atol=2e-6, rtol=2e-6)


def test_dev_scheme_sampling_end_to_end(dataset):
    data, test = dataset
    base = _spec(5, scheme="model_fl", seeds=(0,))
    full = Experiment(data, test, [base]).run(periods=4)
    fullsamp = Experiment(
        data, test,
        [_spec(5, scheme="model_fl", seeds=(0,),
               sampling=Sampling(size=5))]).run(periods=4)
    np.testing.assert_array_equal(np.asarray(full.losses),
                                  np.asarray(fullsamp.losses))
    np.testing.assert_array_equal(full.times, fullsamp.times)
    sub = Experiment(
        data, test,
        [_spec(5, scheme="model_fl", seeds=(0,),
               sampling=Sampling(size=2))]).run(periods=4)
    assert np.all(np.asarray(sub.losses) > 0)
    assert np.all(sub.times[:, -1] < full.times[:, -1])  # smaller cohort,
    #                                       shorter TDMA straggler rounds


def test_audit_certifies_sampled_hier_banded(dataset):
    """run(audit=True) certifies the time-varying-mask, hierarchical and
    banded programs (error findings would raise)."""
    data, test = dataset
    res = Experiment(data, test, [
        _spec(4, seeds=(0,), sampling=Sampling(size=2)),
        _spec(6, seeds=(0,), topology=Topology(cells=2, edges=2,
                                               agg_every=2)),
        _spec(3, seeds=(0,)),
    ]).run(periods=3, audit=True, bands=True)
    assert res.audit is not None and res.audit.ok


def test_serve_bands_split_admission_groups(dataset):
    """With bands=True the service admits per band: a K=3 and a K=7
    arrival (same bucket_key) stay separate micro-batches."""
    from repro.serve import ExperimentService
    from repro.testing import VirtualClock
    data, test = dataset
    clock = VirtualClock()
    svc = ExperimentService(data, test, chunk_periods=2, window=10.0,
                            clock=clock, bands=True)
    t1 = svc.submit(_spec(3, seeds=(0,)), periods=4)
    t2 = svc.submit(_spec(7, seeds=(0,)), periods=4)
    clock.advance(11.0)
    svc.drain()
    assert t1.done and t2.done
    # each admitted alone (different bands -> different groups)
    assert svc.stats.admissions == 2
    r1 = t1.result()
    solo = Experiment(data, test, [_spec(3, seeds=(0,))]).run(periods=4)
    np.testing.assert_array_equal(r1.times, solo.times)
    np.testing.assert_allclose(np.asarray(r1.losses),
                               np.asarray(solo.losses),
                               atol=1e-5, rtol=1e-5)
